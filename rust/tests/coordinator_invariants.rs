//! Property tests on coordinator invariants: routing of jobs to ranks,
//! aggregation semantics, config-state management, and tuner determinism
//! over the distributed path.

use lagom::comm::{CollectiveKind, CommConfig, CommOpDesc};
use lagom::coordinator::{Coordinator, DistributedProfiler, FaultPlan};
use lagom::graph::{CompOpDesc, IterationSchedule, OverlapGroup};
use lagom::hw::ClusterSpec;
use lagom::testing::{for_all, vec_of, Check, Gen};
use lagom::util::units::MIB;
use std::sync::Arc;

fn arb_group<'a>() -> Gen<'a, OverlapGroup> {
    Gen::new(|rng| {
        let comps: Vec<CompOpDesc> = (0..1 + rng.next_below(3))
            .map(|i| {
                let m = 256 << rng.next_below(4);
                CompOpDesc::matmul(format!("mm{i}"), m, 1024, 1024, 2)
            })
            .collect();
        let comms: Vec<CommOpDesc> = (0..1 + rng.next_below(2))
            .map(|i| {
                CommOpDesc::new(
                    format!("ar{i}"),
                    CollectiveKind::AllReduce,
                    (4 + rng.next_below(60)) * MIB,
                    8,
                )
            })
            .collect();
        OverlapGroup::with("g", comps, comms)
    })
}

#[test]
fn invariant_aggregate_is_max_of_ranks() {
    // With one strong straggler, the aggregate must track the straggler —
    // collectives end when the slowest rank does.
    let cl = ClusterSpec::cluster_b(1);
    let g = arb_group();
    for_all("max aggregation", &g, 6, |group| {
        let cfgs = Arc::new(vec![CommConfig::default_ring(); group.comms.len()]);
        let garc = Arc::new(group.clone());
        let mut healthy = Coordinator::spawn(&cl, 11, &[]);
        let mut faults = vec![FaultPlan::healthy(); 8];
        faults[2] = FaultPlan::straggler(3.0);
        let mut slow = Coordinator::spawn(&cl, 11, &faults);
        let mh = healthy.profile(&garc, &cfgs, 2).unwrap();
        let ms = slow.profile(&garc, &cfgs, 2).unwrap();
        healthy.shutdown();
        slow.shutdown();
        Check::from_bool(
            ms.makespan > mh.makespan * 2.0,
            &format!("straggler {} vs healthy {}", ms.makespan, mh.makespan),
        )
    });
}

#[test]
fn invariant_commit_epoch_monotone_and_state_consistent() {
    let cl = ClusterSpec::cluster_b(1);
    let g = vec_of(
        Gen::new(|rng| CommConfig {
            nc: 1 + rng.next_below(60) as u32,
            ..CommConfig::default_ring()
        }),
        1,
        6,
    );
    for_all("commit state", &g, 6, |configs| {
        let mut coord = Coordinator::spawn(&cl, 3, &[]);
        let mut last_epoch = coord.commit_epoch();
        for i in 0..3 {
            let mut cfgs = configs.clone();
            cfgs[0].nc = (i + 1) as u32;
            let acks = coord.commit(cfgs.clone());
            let ok = acks == 8
                && coord.commit_epoch() == last_epoch + 1
                && coord.committed_configs() == cfgs.as_slice();
            if !ok {
                // Leak the coordinator threads (test process ends anyway).
                return Check::Fail(format!("epoch {} acks {acks}", coord.commit_epoch()));
            }
            last_epoch = coord.commit_epoch();
        }
        coord.shutdown();
        Check::Pass
    });
}

#[test]
fn invariant_job_routing_survives_interleaved_ops() {
    // Interleave profile / ping / commit: replies must never cross jobs
    // (stale reports are discarded), so measurements stay well-formed.
    let cl = ClusterSpec::cluster_b(1);
    let g = arb_group();
    for_all("routing", &g, 5, |group| {
        let mut coord = Coordinator::spawn(&cl, 17, &[]);
        let garc = Arc::new(group.clone());
        let cfgs = Arc::new(vec![CommConfig::default_ring(); group.comms.len()]);
        for _ in 0..3 {
            let m = coord.profile(&garc, &cfgs, 1).unwrap();
            if m.comm_times.len() != group.comms.len() || !m.makespan.is_finite() {
                return Check::Fail("malformed measurement".into());
            }
            if coord.ping() != 8 {
                return Check::Fail("ping lost ranks".into());
            }
            coord.commit(cfgs.to_vec());
        }
        coord.shutdown();
        Check::Pass
    });
}

#[test]
fn invariant_tuner_results_equivalent_local_vs_distributed() {
    // Same tuner, same seed stream shape: the distributed backend must
    // produce a config of comparable quality (not identical — noise
    // streams differ — but within a tolerance band on the evaluated
    // makespan).
    use lagom::profiler::SimProfiler;
    use lagom::report::evaluate;
    use lagom::sim::SimEnv;
    use lagom::tuner::{LagomTuner, Tuner};
    let cl = ClusterSpec::cluster_b(1);
    let group = OverlapGroup::with(
        "eq",
        vec![
            CompOpDesc::ffn("ffn0", 2048, 2560, 10240, 2),
            CompOpDesc::ffn("ffn1", 2048, 2560, 10240, 2),
        ],
        vec![CommOpDesc::new("ar", CollectiveKind::AllReduce, 32 * MIB, 8)],
    );
    let mut s = IterationSchedule::new("eq");
    s.push(group);

    let mut local = SimProfiler::new(SimEnv::new(cl.clone(), 23));
    let rl = LagomTuner::new(cl.clone()).tune_schedule(&s, &mut local);

    let coord = Coordinator::spawn(&cl, 23, &[]);
    let mut dist = DistributedProfiler::new(coord);
    let rd = LagomTuner::new(cl.clone()).tune_schedule(&s, &mut dist);
    dist.coord.shutdown();

    let zl = evaluate(&s, &rl.configs, &cl, 1, 99);
    let zd = evaluate(&s, &rd.configs, &cl, 1, 99);
    assert!(
        (zd - zl).abs() / zl < 0.08,
        "local {zl} vs distributed {zd}"
    );
}

#[test]
fn invariant_world_size_matches_cluster() {
    for (cl, expect) in [
        (ClusterSpec::cluster_a(1), 8),
        (ClusterSpec::cluster_b(2), 16),
    ] {
        let coord = Coordinator::spawn(&cl, 1, &[]);
        assert_eq!(coord.world_size(), expect);
        assert_eq!(coord.alive_ranks(), expect);
        coord.shutdown();
    }
}
