//! Failure injection on the coordinator: dead workers, stragglers,
//! transient unresponsiveness, rank rehabilitation, degraded-mode
//! fallback, tuning under degraded membership, and crash-safe campaign
//! resume.

use lagom::comm::{CollectiveKind, CommConfig, CommOpDesc};
use lagom::coordinator::{Coordinator, DistributedProfiler, FaultPlan, RankState};
use lagom::graph::{CompOpDesc, IterationSchedule, OverlapGroup};
use lagom::hw::ClusterSpec;
use lagom::profiler::ProfileBackend;
use lagom::tuner::{LagomTuner, Tuner};
use lagom::util::units::MIB;
use std::sync::Arc;
use std::time::Duration;

fn group() -> OverlapGroup {
    OverlapGroup::with(
        "g",
        vec![CompOpDesc::ffn("ffn", 1024, 1024, 4096, 2)],
        vec![CommOpDesc::new("ar", CollectiveKind::AllReduce, 8 * MIB, 8)],
    )
}

#[test]
fn single_dead_worker_does_not_block_progress() {
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::healthy(); 8];
    faults[4] = FaultPlan::dies_after(2);
    let mut coord = Coordinator::spawn(&cl, 7, &faults);
    coord.timeout = Duration::from_millis(250);
    let g = Arc::new(group());
    let c = Arc::new(vec![CommConfig::default_ring()]);
    for i in 0..6 {
        let m = coord.profile(&g, &c, 1);
        assert!(m.is_some(), "round {i} must still aggregate");
    }
    assert_eq!(coord.alive_ranks(), 7, "dead rank detected exactly once");
    coord.shutdown();
}

#[test]
fn majority_failure_still_returns_measurements() {
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::dies_after(1); 8];
    faults[0] = FaultPlan::healthy();
    let mut coord = Coordinator::spawn(&cl, 9, &faults);
    coord.timeout = Duration::from_millis(250);
    let g = Arc::new(group());
    let c = Arc::new(vec![CommConfig::default_ring()]);
    assert!(coord.profile(&g, &c, 1).is_some());
    assert!(coord.profile(&g, &c, 1).is_some(), "survivor keeps reporting");
    assert_eq!(coord.alive_ranks(), 1);
    coord.shutdown();
}

#[test]
fn tuning_completes_with_straggler_and_casualty() {
    // Lagom over a degraded coordinator: a straggler skews measurements
    // upward and one rank dies mid-tuning; tuning must still converge to a
    // valid config set.
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::healthy(); 8];
    faults[1] = FaultPlan::straggler(1.5);
    faults[6] = FaultPlan::dies_after(10);
    let mut coord = Coordinator::spawn(&cl, 13, &faults);
    coord.timeout = Duration::from_millis(250);
    let mut backend = DistributedProfiler::new(coord);
    backend.reps = 1;

    let mut s = IterationSchedule::new("faulty");
    s.push(group());
    let mut tuner = LagomTuner::new(cl.clone());
    let r = tuner.tune_schedule(&s, &mut backend);
    assert_eq!(r.configs.len(), 1);
    let space = lagom::comm::ParamSpace::default();
    assert!(r.configs[0].nc >= space.nc_min && r.configs[0].nc <= space.nc_max);
    assert!(backend.coord.alive_ranks() < 8, "casualty happened during tuning");
    backend.coord.shutdown();
}

#[test]
fn commit_acks_reflect_dead_ranks() {
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::healthy(); 8];
    faults[3] = FaultPlan::dies_after(0);
    let mut coord = Coordinator::spawn(&cl, 15, &faults);
    coord.timeout = Duration::from_millis(250);
    // First commit: rank 3 never replies -> timeout -> 7 acks.
    let acks = coord.commit(vec![CommConfig::default_ring()]);
    assert_eq!(acks, 7);
    assert_eq!(coord.alive_ranks(), 7);
    // Second commit: no timeout path, still 7.
    let t0 = std::time::Instant::now();
    let acks2 = coord.commit(vec![CommConfig::default_ring()]);
    assert_eq!(acks2, 7);
    assert!(t0.elapsed() < Duration::from_millis(200));
    coord.shutdown();
}

#[test]
fn shutdown_is_idempotent_under_faults() {
    let cl = ClusterSpec::cluster_b(1);
    let faults = vec![FaultPlan::dies_after(0); 8];
    let mut coord = Coordinator::spawn(&cl, 17, &faults);
    coord.timeout = Duration::from_millis(100);
    let _ = coord.ping();
    coord.shutdown(); // must not hang on dead workers
}

#[test]
fn transient_unresponsive_rank_is_suspected_rehabilitated_and_resynced() {
    // A rank that goes silent for two jobs must walk Alive -> Suspect and
    // back to Alive via re-sync — never through Dead.
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::healthy(); 8];
    faults[2] = FaultPlan::transient(1, 3); // mute for job ordinals 1 and 2
    let mut coord = Coordinator::spawn(&cl, 19, &faults);
    coord.timeout = Duration::from_millis(150);
    let g = Arc::new(group());
    let c = Arc::new(vec![CommConfig::default_ring()]);

    // Ordinal 0: everyone healthy.
    assert!(coord.profile(&g, &c, 1).is_some());
    assert_eq!(coord.alive_ranks(), 8);

    // Ordinal 1: rank 2 swallows the commit. Quorum still holds, so the
    // epoch advances without it and the rank shows up as divergent.
    let out = coord.try_commit(vec![CommConfig::default_ring()]);
    assert!(out.committed);
    assert_eq!((out.acks, out.sent, out.epoch), (7, 8, 1));
    assert_eq!(coord.epoch_divergence(), vec![2]);
    assert_eq!(coord.rank_state(2), RankState::Suspect);

    // Ordinal 2: still muted — a second miss, but below the death threshold.
    assert!(coord.profile(&g, &c, 1).is_some());
    assert_eq!(coord.rank_state(2), RankState::Suspect);

    // Ordinal 3: the rank answers again. Its epoch is stale, so the leader
    // replays the committed state before counting it alive.
    assert!(coord.profile(&g, &c, 1).is_some());
    coord.drain_rejoins(Duration::from_secs(5));
    assert_eq!(coord.rank_state(2), RankState::Alive);
    assert!(coord.epoch_divergence().is_empty(), "re-sync reconciled the epoch");

    let hr = coord.health_report();
    assert_eq!(hr.alive, 8);
    assert_eq!(hr.stats.deaths, 0, "transient fault must never kill the rank");
    assert_eq!(hr.stats.rejoins, 1);
    assert!(hr.stats.suspects >= 1);
    coord.shutdown();
}

#[test]
fn all_ranks_dead_falls_back_to_local_measurement() {
    // When the whole world dies mid-tuning, the profiler must degrade to a
    // tagged local measurement instead of panicking.
    let cl = ClusterSpec::cluster_b(1);
    let faults = vec![FaultPlan::dies_after(2); 8];
    let coord = Coordinator::spawn(&cl, 21, &faults);
    let mut backend = DistributedProfiler::new(coord);
    backend.coord.timeout = Duration::from_millis(100);
    backend.reps = 1;

    let mut s = IterationSchedule::new("doomed");
    s.push(group());
    let mut tuner = LagomTuner::new(cl.clone());
    let r = tuner.tune_schedule(&s, &mut backend);
    assert_eq!(r.configs.len(), 1);
    let space = lagom::comm::ParamSpace::default();
    assert!(r.configs[0].nc >= space.nc_min && r.configs[0].nc <= space.nc_max);

    let hr = backend.health_report();
    assert_eq!(hr.dead, 8, "every rank died");
    assert_eq!(hr.alive, 0);
    assert!(hr.fallbacks > 0, "local fallback served the remaining jobs");
    backend.coord.shutdown();
}

#[test]
fn broadcast_on_empty_world_short_circuits() {
    let cl = ClusterSpec::cluster_b(1);
    let faults = vec![FaultPlan::dies_after(0); 8];
    let mut coord = Coordinator::spawn(&cl, 23, &faults);
    coord.timeout = Duration::from_millis(200);
    // Round 1: every worker consumes its first message and exits -> all miss.
    assert_eq!(coord.ping(), 0);
    // Round 2: the channels are closed, sends fail, every rank is Dead.
    let _ = coord.ping();
    assert_eq!(coord.health_report().dead, 8);

    // With nobody left, nothing may burn a timeout or a job id.
    let g = Arc::new(group());
    let c = Arc::new(vec![CommConfig::default_ring()]);
    let t0 = std::time::Instant::now();
    assert!(coord.profile(&g, &c, 1).is_none());
    assert_eq!(coord.ping(), 0);
    let out = coord.try_commit(vec![CommConfig::default_ring()]);
    assert_eq!((out.acks, out.sent), (0, 0));
    assert!(!out.committed);
    assert_eq!(coord.commit_epoch(), 0);
    assert!(
        t0.elapsed() < Duration::from_millis(50),
        "empty world must short-circuit, not wait out deadlines"
    );
    coord.shutdown();
}

#[test]
fn des_straggler_stretches_makespan_exactly_and_deterministically() {
    // The discrete-event tier consumes the same FaultPlan the coordinator
    // chaos layer uses: a 2x straggler on node 1 of a 2-node cluster must
    // bound the fleet and stretch its makespan by exactly 2.0 (the
    // straggle TimeMap is one exact multiply), leaving the healthy class
    // bitwise-untouched.
    use lagom::sim::{simulate_group_des, SimEnv};
    let cl = ClusterSpec::cluster_b(2);
    let g = group();
    let c = vec![CommConfig::default_ring()];
    let healthy = simulate_group_des(&g, &c, &mut SimEnv::deterministic(cl.clone()), &[]);
    let mut faults = vec![FaultPlan::healthy(); 2];
    faults[1] = FaultPlan::straggler(2.0);
    let d = simulate_group_des(&g, &c, &mut SimEnv::deterministic(cl), &faults);
    assert_eq!(d.critical_class, 1, "the straggling node bounds the fleet");
    assert_eq!(d.makespan, 2.0 * healthy.makespan, "2x straggler stretches exactly 2x");
    assert_eq!(d.comm_total, 2.0 * healthy.comm_total, "comm stretches with it");
    assert_eq!(d.class_makespans[0], healthy.makespan, "healthy class untouched");
    assert!(d.nic_skew > 0.0, "the NIC observes the inter-class skew");
}

#[test]
fn des_straggler_replays_identically_under_same_chaos_seed() {
    // Noisy DES runs fork one PRNG stream per rank class, tagged with the
    // fault plan's chaos seed — the same replay contract the coordinator
    // prints in health reports: same seeds, bitwise-identical schedule.
    use lagom::sim::{simulate_group_des, SimEnv};
    let cl = ClusterSpec::cluster_b(2);
    let g = group();
    let c = vec![CommConfig::default_ring()];
    let mut faults = vec![FaultPlan::healthy(); 2];
    faults[1] = FaultPlan { chaos_seed: 0xC0FFEE, ..FaultPlan::straggler(2.0) };
    let run = |faults: &[FaultPlan]| {
        let mut env = SimEnv::new(cl.clone(), 42);
        simulate_group_des(&g, &c, &mut env, faults)
    };
    let a = run(&faults);
    let b = run(&faults);
    assert_eq!(a, b, "same seed + same chaos seed replays bitwise");
    assert_eq!(a.critical_class, 1);
    faults[1].chaos_seed = 0xBEEF;
    assert_ne!(a.makespan, run(&faults).makespan, "chaos seed is part of the schedule");
}

#[test]
fn campaign_resumes_from_checkpoint_bitwise_identical() {
    // Kill a campaign between scenarios (simulated by simply stopping after
    // a prefix, never calling the final save) and resume it from the
    // periodic checkpoint: the leaderboard must come out bitwise identical
    // to an uninterrupted run.
    use lagom::campaign::{
        run_campaign, scenario_grid, CampaignConfig, Leaderboard, ResultCache, Scenario,
    };

    let grid: Vec<Scenario> = scenario_grid(Some(1)).into_iter().take(3).collect();
    // jobs: 1 keeps checkpoint saves sequential, so the last one on disk
    // deterministically holds every scenario measured so far.
    let cfg =
        CampaignConfig { seed: 4242, jobs: 1, checkpoint_every: 1, ..CampaignConfig::default() };

    // Reference: uninterrupted, purely in-memory.
    let reference = run_campaign(&grid, &cfg, &ResultCache::in_memory());
    let reference_json = Leaderboard::from_result(&reference).to_json_canonical().to_pretty();

    let path = std::env::temp_dir().join(format!("lagom_ckpt_resume_{}.json", std::process::id()));
    let _ = std::fs::remove_file(&path);

    // "Crashed" run: measure only the first two scenarios. The periodic
    // checkpoint (every scenario) persists them; we never call save().
    {
        let cache = ResultCache::open(&path);
        let partial = run_campaign(&grid[..2], &cfg, &cache);
        assert_eq!(partial.outcomes.len(), 2);
        // cache dropped here without an explicit save — the crash.
    }

    // Resume: the checkpoint file has both finished scenarios.
    let cache = ResultCache::open(&path);
    assert_eq!(cache.len(), 2, "periodic checkpoint survived the crash");
    let resumed = run_campaign(&grid, &cfg, &cache);
    assert_eq!(resumed.cache_hits, 2);
    assert_eq!(resumed.cache_misses, 1);
    let resumed_json = Leaderboard::from_result(&resumed).to_json_canonical().to_pretty();

    assert_eq!(reference_json, resumed_json, "resume must be bitwise identical");
    let _ = std::fs::remove_file(&path);
}
