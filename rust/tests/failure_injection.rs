//! Failure injection on the coordinator: dead workers, stragglers, and
//! tuning under degraded membership.

use lagom::comm::{CollectiveKind, CommConfig, CommOpDesc};
use lagom::coordinator::{Coordinator, DistributedProfiler, FaultPlan};
use lagom::graph::{CompOpDesc, IterationSchedule, OverlapGroup};
use lagom::hw::ClusterSpec;
use lagom::profiler::ProfileBackend;
use lagom::tuner::{LagomTuner, Tuner};
use lagom::util::units::MIB;
use std::sync::Arc;
use std::time::Duration;

fn group() -> OverlapGroup {
    OverlapGroup::with(
        "g",
        vec![CompOpDesc::ffn("ffn", 1024, 1024, 4096, 2)],
        vec![CommOpDesc::new("ar", CollectiveKind::AllReduce, 8 * MIB, 8)],
    )
}

#[test]
fn single_dead_worker_does_not_block_progress() {
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::healthy(); 8];
    faults[4] = FaultPlan::dies_after(2);
    let mut coord = Coordinator::spawn(&cl, 7, &faults);
    coord.timeout = Duration::from_millis(250);
    let g = Arc::new(group());
    let c = Arc::new(vec![CommConfig::default_ring()]);
    for i in 0..6 {
        let m = coord.profile(&g, &c, 1);
        assert!(m.is_some(), "round {i} must still aggregate");
    }
    assert_eq!(coord.alive_ranks(), 7, "dead rank detected exactly once");
    coord.shutdown();
}

#[test]
fn majority_failure_still_returns_measurements() {
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::dies_after(1); 8];
    faults[0] = FaultPlan::healthy();
    let mut coord = Coordinator::spawn(&cl, 9, &faults);
    coord.timeout = Duration::from_millis(250);
    let g = Arc::new(group());
    let c = Arc::new(vec![CommConfig::default_ring()]);
    assert!(coord.profile(&g, &c, 1).is_some());
    assert!(coord.profile(&g, &c, 1).is_some(), "survivor keeps reporting");
    assert_eq!(coord.alive_ranks(), 1);
    coord.shutdown();
}

#[test]
fn tuning_completes_with_straggler_and_casualty() {
    // Lagom over a degraded coordinator: a straggler skews measurements
    // upward and one rank dies mid-tuning; tuning must still converge to a
    // valid config set.
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::healthy(); 8];
    faults[1] = FaultPlan::straggler(1.5);
    faults[6] = FaultPlan::dies_after(10);
    let mut coord = Coordinator::spawn(&cl, 13, &faults);
    coord.timeout = Duration::from_millis(250);
    let mut backend = DistributedProfiler::new(coord);
    backend.reps = 1;

    let mut s = IterationSchedule::new("faulty");
    s.push(group());
    let mut tuner = LagomTuner::new(cl.clone());
    let r = tuner.tune_schedule(&s, &mut backend);
    assert_eq!(r.configs.len(), 1);
    let space = lagom::comm::ParamSpace::default();
    assert!(r.configs[0].nc >= space.nc_min && r.configs[0].nc <= space.nc_max);
    assert!(backend.coord.alive_ranks() < 8, "casualty happened during tuning");
    backend.coord.shutdown();
}

#[test]
fn commit_acks_reflect_dead_ranks() {
    let cl = ClusterSpec::cluster_b(1);
    let mut faults = vec![FaultPlan::healthy(); 8];
    faults[3] = FaultPlan::dies_after(0);
    let mut coord = Coordinator::spawn(&cl, 15, &faults);
    coord.timeout = Duration::from_millis(250);
    // First commit: rank 3 never replies -> timeout -> 7 acks.
    let acks = coord.commit(vec![CommConfig::default_ring()]);
    assert_eq!(acks, 7);
    assert_eq!(coord.alive_ranks(), 7);
    // Second commit: no timeout path, still 7.
    let t0 = std::time::Instant::now();
    let acks2 = coord.commit(vec![CommConfig::default_ring()]);
    assert_eq!(acks2, 7);
    assert!(t0.elapsed() < Duration::from_millis(200));
    coord.shutdown();
}

#[test]
fn shutdown_is_idempotent_under_faults() {
    let cl = ClusterSpec::cluster_b(1);
    let faults = vec![FaultPlan::dies_after(0); 8];
    let mut coord = Coordinator::spawn(&cl, 17, &faults);
    coord.timeout = Duration::from_millis(100);
    let _ = coord.ping();
    coord.shutdown(); // must not hang on dead workers
}
