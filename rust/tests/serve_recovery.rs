//! Crash-recovery property tests for the tuning daemon: tear the
//! write-ahead journal at arbitrary byte offsets (simulating `kill -9`
//! mid-append), restart, and require the replay to be *bitwise identical*
//! to the uninterrupted reference run — with no request evaluated twice.

use lagom::campaign::ResultCache;
use lagom::eval::EvalMode;
use lagom::serve::{Journal, ServiceConfig, Status, TuneRequest, TuningService};
use lagom::util::prng::splitmix64;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

fn req(seed: u64) -> TuneRequest {
    TuneRequest {
        cluster: "b8".to_string(),
        model: "phi2".to_string(),
        par: "fsdp".to_string(),
        mbs: 2,
        layers: 1,
        seed,
        fidelity: EvalMode::Analytic,
        deadline_ms: 0,
    }
}

fn cfg() -> ServiceConfig {
    ServiceConfig { slots: 1, queue: 8, ..ServiceConfig::default() }
}

fn tmp(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lagom_serve_rec_{tag}_{}.wal", std::process::id()))
}

/// Serve `reqs` serially on a fresh journal; return each response's
/// canonical serialized form keyed by request id, plus the number of
/// fresh evaluations the run performed.
fn run_reference(path: &Path, reqs: &[TuneRequest]) -> (BTreeMap<u64, String>, u64) {
    let _ = std::fs::remove_file(path);
    let svc = TuningService::new(
        cfg(),
        ResultCache::in_memory(),
        Some(Journal::open(path).unwrap()),
    );
    let mut by_id = BTreeMap::new();
    for r in reqs {
        let resp = svc.handle(r);
        assert_eq!(resp.status, Status::Served, "reference run must be clean");
        by_id.insert(resp.id, resp.to_json().to_string());
    }
    (by_id, svc.fresh_measures())
}

#[test]
fn torn_journal_at_arbitrary_offsets_replays_bitwise_identically() {
    // Five requests, one a content-duplicate of the first (seeds are part
    // of result identity, so seed 1 twice is the same work twice).
    let reqs = vec![req(1), req(2), req(3), req(1), req(4)];
    let ref_path = tmp("ref");
    let (reference, ref_fresh) = run_reference(&ref_path, &reqs);
    let full = std::fs::read(&ref_path).unwrap();
    let _ = std::fs::remove_file(&ref_path);
    assert_eq!(reference.len(), 5);
    assert_eq!(ref_fresh, 4, "the duplicate must be a cache hit even when fresh");

    // Crash points: every record boundary (clean truncations) plus a
    // spread of seeded random offsets (torn mid-record, mid-prefix,
    // mid-checksum — wherever they land).
    let mut cuts: Vec<usize> = vec![0, full.len()];
    let mut i = 0usize;
    while i + 12 <= full.len() {
        let len = u32::from_le_bytes([full[i], full[i + 1], full[i + 2], full[i + 3]]) as usize;
        i += 12 + len;
        if i <= full.len() {
            cuts.push(i);
        }
    }
    let mut s = 0x5eed_cafe_u64;
    for _ in 0..24 {
        cuts.push(splitmix64(&mut s) as usize % (full.len() + 1));
    }
    cuts.sort_unstable();
    cuts.dedup();

    let crash_path = tmp("crash");
    for &cut in &cuts {
        std::fs::write(&crash_path, &full[..cut]).unwrap();
        let svc = TuningService::new(
            cfg(),
            ResultCache::in_memory(),
            Some(Journal::open(&crash_path).unwrap()),
        );
        let rec = svc.recover();
        let mut by_id: BTreeMap<u64, String> = BTreeMap::new();
        for doc in &rec.responses {
            let id = doc.get("id").and_then(|v| v.as_u64()).unwrap();
            by_id.insert(id, doc.to_string());
        }
        // The journal covers a prefix of the ids; resubmit the lost
        // suffix exactly as a retrying client would. Ids must line up
        // because next_id resumes past the highest journaled id.
        for (idx, r) in reqs.iter().enumerate() {
            let id = (idx + 1) as u64;
            if !by_id.contains_key(&id) {
                let resp = svc.handle(r);
                assert_eq!(resp.id, id, "cut {cut}: ids resume past the journal");
                by_id.insert(resp.id, resp.to_json().to_string());
            }
        }
        assert_eq!(by_id, reference, "cut {cut}: replay must be bitwise identical");
        assert!(
            svc.fresh_measures() <= ref_fresh,
            "cut {cut}: recovery never evaluates more than a cold run ({} vs {ref_fresh})",
            svc.fresh_measures()
        );
        if cut == full.len() {
            assert_eq!(rec.reserved, 5, "intact journal: everything re-served");
            assert_eq!(rec.reevaluated, 0);
            assert_eq!(svc.fresh_measures(), 0, "intact journal: zero re-evaluation");
        }
    }
    let _ = std::fs::remove_file(&crash_path);
}

#[test]
fn recovery_after_recovery_is_pure_replay() {
    // Crashing *after* a successful recovery must change nothing: the
    // journal the first recovery extended replays to the same answers with
    // zero evaluation, as many times as it takes.
    let path = tmp("idem");
    let reqs = vec![req(10), req(11), req(10)];
    let (reference, _) = run_reference(&path, &reqs);
    for round in 0..2 {
        let svc = TuningService::new(
            cfg(),
            ResultCache::in_memory(),
            Some(Journal::open(&path).unwrap()),
        );
        let rec = svc.recover();
        assert_eq!(rec.reserved, 3, "round {round}");
        assert_eq!(rec.reevaluated, 0, "round {round}");
        assert_eq!(svc.fresh_measures(), 0, "round {round}: replay is free");
        let by_id: BTreeMap<u64, String> = rec
            .responses
            .iter()
            .map(|d| (d.get("id").and_then(|v| v.as_u64()).unwrap(), d.to_string()))
            .collect();
        assert_eq!(by_id, reference, "round {round}");
    }
    let _ = std::fs::remove_file(&path);
}

#[test]
fn new_requests_after_recovery_reuse_the_recovered_cache() {
    let path = tmp("resume");
    let (reference, ref_fresh) = run_reference(&path, &[req(20), req(21)]);
    let svc = TuningService::new(
        cfg(),
        ResultCache::in_memory(),
        Some(Journal::open(&path).unwrap()),
    );
    svc.recover();
    assert_eq!(svc.fresh_measures(), 0);
    // A repeat of a recovered scenario is answered from the rebuilt cache;
    // only genuinely new content is measured.
    let repeat = svc.handle(&req(20));
    assert_eq!(repeat.id, 3, "ids continue past the journal");
    assert_eq!(svc.fresh_measures(), 0, "recovered results serve repeats");
    let repeat_doc = repeat.to_json();
    let outcome_of = |s: &str| {
        lagom::util::json::Json::parse(s).unwrap().get("outcome").unwrap().to_string()
    };
    assert_eq!(
        repeat_doc.get("outcome").unwrap().to_string(),
        outcome_of(&reference[&1]),
        "same content, same numbers"
    );
    let fresh = svc.handle(&req(22));
    assert_eq!(fresh.status, Status::Served);
    assert_eq!(svc.fresh_measures(), 1, "new content is measured exactly once");
    assert!(ref_fresh >= 2);
    let _ = std::fs::remove_file(&path);
}
