//! Overload behaviour of the tuning daemon: drive the service at 4× its
//! drain capacity and require every request to end in a terminal response
//! — served, degraded, or an explicit shed with an actionable retry-after
//! hint. Silent drops and unbounded queues are the failure modes under
//! test. Also covers the Unix-socket front end end to end.

use lagom::campaign::ResultCache;
use lagom::eval::EvalMode;
use lagom::serve::{
    client_request, serve, ServerOptions, ServiceConfig, Status, TuneRequest, TuningService,
};
use lagom::util::json::Json;
use std::path::PathBuf;
use std::sync::{Arc, Barrier};

fn req(seed: u64) -> TuneRequest {
    TuneRequest {
        cluster: "b8".to_string(),
        model: "phi2".to_string(),
        par: "fsdp".to_string(),
        mbs: 2,
        layers: 1,
        seed,
        fidelity: EvalMode::Analytic,
        deadline_ms: 0,
    }
}

#[test]
fn four_x_capacity_is_all_terminal_with_zero_silent_drops() {
    // Capacity = 2 slots + 2 waiting = 4; offered load = 16 concurrent.
    let cap = 2usize;
    let svc = Arc::new(TuningService::new(
        ServiceConfig { slots: 2, queue: 2, ..ServiceConfig::default() },
        ResultCache::in_memory().with_capacity(cap),
        None,
    ));
    let n = 16usize;
    let barrier = Arc::new(Barrier::new(n));
    let mut handles = Vec::new();
    for i in 0..n {
        let svc = Arc::clone(&svc);
        let barrier = Arc::clone(&barrier);
        handles.push(std::thread::spawn(move || {
            barrier.wait();
            svc.handle(&req(100 + i as u64))
        }));
    }
    let responses: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // Accountability: exactly one terminal response per submission.
    assert_eq!(responses.len(), n, "zero silent drops");
    let shed: Vec<_> = responses.iter().filter(|r| r.status == Status::Shed).collect();
    let answered: Vec<_> = responses
        .iter()
        .filter(|r| matches!(r.status, Status::Served | Status::Degraded))
        .collect();
    assert_eq!(shed.len() + answered.len(), n, "every status is terminal");
    assert_eq!(svc.admitted_count() + svc.shed_count(), n as u64);
    assert_eq!(svc.shed_count(), shed.len() as u64);

    // 16 simultaneous arrivals against capacity 4: overload must actually
    // shed, and every shed carries an actionable backpressure hint.
    assert!(!shed.is_empty(), "4x load must trip admission control");
    assert!(!answered.is_empty(), "admitted work still completes under overload");
    for r in &shed {
        assert!(r.retry_after_ms.unwrap_or(0) >= 1, "shed without a retry hint");
        assert!(r.outcome.is_none());
    }
    for r in &answered {
        assert!(r.outcome.is_some(), "answered requests carry numbers");
        assert!(r.id > 0);
    }

    // Bounded memory under load: the LRU cap held even though more unique
    // scenarios than `cap` were admitted.
    assert!(svc.cache().len() <= cap, "resident cache exceeded its cap");
    assert!(svc.cache().evictions() >= 1, "overload churned the LRU");
}

fn sock(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("lagom_serve_{tag}_{}.sock", std::process::id()))
}

fn tune_doc(r: &TuneRequest) -> Json {
    let mut doc = r.to_json();
    if let Json::Obj(m) = &mut doc {
        m.insert("kind".to_string(), Json::str("tune"));
    }
    doc
}

fn await_socket(path: &PathBuf) {
    for _ in 0..2000 {
        if path.exists() {
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
    panic!("daemon socket {path:?} never appeared");
}

#[test]
fn socket_round_trip_tune_stats_shutdown() {
    let path = sock("rt");
    let _ = std::fs::remove_file(&path);
    let svc = Arc::new(TuningService::new(
        ServiceConfig::default(),
        ResultCache::in_memory(),
        None,
    ));
    let (svc2, path2) = (Arc::clone(&svc), path.clone());
    let daemon =
        std::thread::spawn(move || serve(svc2, &path2, ServerOptions::default()).unwrap());
    await_socket(&path);

    let resp = client_request(&path, &tune_doc(&req(7))).unwrap();
    assert_eq!(resp.get("status").and_then(|s| s.as_str()), Some("served"));
    assert_eq!(resp.get("id").and_then(|i| i.as_u64()), Some(1));
    assert!(resp.get("outcome").is_some_and(|o| *o != Json::Null));

    let stats = client_request(&path, &Json::obj(vec![("kind", Json::str("stats"))])).unwrap();
    assert_eq!(stats.get("schema").and_then(|s| s.as_str()), Some("lagom.serve.stats/v1"));
    assert_eq!(stats.get("served").and_then(|v| v.as_u64()), Some(1));

    // Malformed tune envelopes get terminal error responses, not hangups.
    let bad = client_request(&path, &Json::obj(vec![("kind", Json::str("tune"))])).unwrap();
    assert_eq!(bad.get("status").and_then(|s| s.as_str()), Some("error"));

    let ack = client_request(&path, &Json::obj(vec![("kind", Json::str("shutdown"))])).unwrap();
    assert_eq!(ack.get("ok").and_then(|b| b.as_bool()), Some(true));
    let report = daemon.join().unwrap();
    assert_eq!(report.tune_requests, 2, "both tune envelopes count, malformed included");
    assert!(!path.exists(), "socket file cleaned up on shutdown");
}

#[test]
fn max_requests_drains_and_exits_without_a_shutdown_message() {
    let path = sock("max");
    let _ = std::fs::remove_file(&path);
    let svc = Arc::new(TuningService::new(
        ServiceConfig::default(),
        ResultCache::in_memory(),
        None,
    ));
    let (svc2, path2) = (Arc::clone(&svc), path.clone());
    let daemon = std::thread::spawn(move || {
        serve(svc2, &path2, ServerOptions { max_requests: 2 }).unwrap()
    });
    await_socket(&path);
    let a = client_request(&path, &tune_doc(&req(40))).unwrap();
    let b = client_request(&path, &tune_doc(&req(41))).unwrap();
    assert_eq!(a.get("status").and_then(|s| s.as_str()), Some("served"));
    assert_eq!(b.get("status").and_then(|s| s.as_str()), Some("served"));
    let report = daemon.join().unwrap();
    assert_eq!(report.tune_requests, 2, "limit reached, daemon drained");
}
