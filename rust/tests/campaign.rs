//! Campaign subsystem integration tests: cache hit/miss semantics across
//! process-like reopen, deterministic leaderboard ordering under a fixed
//! seed, and the property that on every grid scenario the Lagom-tuned
//! iteration is at least as fast as the NCCL baseline (up to the
//! simulator's measurement-noise tolerance).

use lagom::campaign::{
    run_campaign, scenario_grid, CacheKey, CampaignConfig, Leaderboard, ResultCache, Scenario,
};
use lagom::testing::{for_all, Check, Gen};

/// A small but heterogeneous slice of the grid (both clusters, several
/// strategies) that keeps test wall time in check.
fn small_grid() -> Vec<Scenario> {
    let grid = scenario_grid(Some(1));
    // Every 5th scenario: spans both bw classes and several strategies.
    grid.into_iter().step_by(5).collect()
}

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("lagom_campaign_test_{tag}_{}.json", std::process::id()))
}

#[test]
fn cache_misses_then_hits_across_reopen() {
    let grid = small_grid();
    let path = tmp_path("reopen");
    let _ = std::fs::remove_file(&path);
    let config = CampaignConfig::default();

    // Cold: every scenario is a miss and gets measured.
    let cache = ResultCache::open(&path);
    let r1 = run_campaign(&grid, &config, &cache);
    assert_eq!(r1.cache_misses, grid.len() as u64);
    assert_eq!(r1.cache_hits, 0);
    assert!(r1.outcomes.iter().all(|o| !o.cached));
    cache.save().unwrap();

    // Reopened (second invocation): every scenario is a hit, numbers match.
    let cache2 = ResultCache::open(&path);
    let r2 = run_campaign(&grid, &config, &cache2);
    assert_eq!(r2.cache_hits, grid.len() as u64);
    assert_eq!(r2.cache_misses, 0);
    for (a, b) in r1.outcomes.iter().zip(&r2.outcomes) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.nccl_iter, b.nccl_iter);
        assert_eq!(a.lagom_iter, b.lagom_iter);
        assert!(b.cached);
    }

    // A different seed is a different tuning problem: cold again.
    let cache3 = ResultCache::open(&path);
    let r3 = run_campaign(
        &grid,
        &CampaignConfig { seed: 43, ..CampaignConfig::default() },
        &cache3,
    );
    assert_eq!(r3.cache_misses, grid.len() as u64, "seed is part of the key");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn cache_keys_unique_across_grid() {
    let grid = scenario_grid(Some(2));
    let config = CampaignConfig::default();
    let mut keys: Vec<CacheKey> = grid
        .iter()
        .map(|s| {
            CacheKey::of(&s.cluster, &s.workload, &config.space, config.seed, config.fidelity)
        })
        .collect();
    let n = keys.len();
    keys.sort_unstable();
    keys.dedup();
    assert_eq!(keys.len(), n, "every scenario hashes to a distinct key");
}

#[test]
fn leaderboard_deterministic_under_fixed_seed() {
    let grid = small_grid();
    let config = CampaignConfig::default();
    let r1 = run_campaign(&grid, &config, &ResultCache::in_memory());
    let r2 = run_campaign(&grid, &config, &ResultCache::in_memory());
    let j1 = Leaderboard::from_result(&r1).to_json().to_pretty();
    let j2 = Leaderboard::from_result(&r2).to_json().to_pretty();
    // Strip the only nondeterministic field (wall-clock) before comparing.
    let scrub = |s: &str| -> String {
        s.lines().filter(|l| !l.contains("wall_secs")).collect::<Vec<_>>().join("\n")
    };
    assert_eq!(scrub(&j1), scrub(&j2), "same seed, same leaderboard");

    // And the ordering is the documented one: speedup desc, id asc.
    let lb = Leaderboard::from_result(&r1);
    for w in lb.rows.windows(2) {
        assert!(
            w[0].lagom_vs_nccl > w[1].lagom_vs_nccl
                || (w[0].lagom_vs_nccl == w[1].lagom_vs_nccl && w[0].id < w[1].id),
            "rows must be strictly ordered"
        );
    }
}

#[test]
fn prop_lagom_never_loses_to_nccl_on_any_grid_scenario() {
    // Property: for a random grid scenario and seed, the Lagom-tuned
    // iteration time is <= the NCCL baseline's, within the simulator's
    // noise tolerance (3%, the bar the repo's integration tests use).
    let grid = scenario_grid(Some(1));
    let n = grid.len() as u64;
    let g = Gen::new(move |rng| (rng.next_below(n) as usize, 1 + rng.next_below(1000)));
    for_all("lagom <= nccl per scenario", &g, 8, |&(idx, seed)| {
        let scenario = grid[idx].clone();
        let cache = ResultCache::in_memory();
        let config = CampaignConfig { seed, ..CampaignConfig::default() };
        let r = run_campaign(&[scenario], &config, &cache);
        let o = &r.outcomes[0];
        Check::from_bool(
            o.lagom_iter <= o.nccl_iter * 1.03,
            &format!("{}: lagom {} vs nccl {} (seed {seed})", o.id, o.lagom_iter, o.nccl_iter),
        )
    });
}
