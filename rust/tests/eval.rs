//! Cross-tier integration tests for the evaluation layer: analytic vs
//! simulated agreement on the Table-2-style fixtures, tiered-tuning
//! safety, and evaluation-cache semantics.

use lagom::comm::{CollectiveKind, CommConfig, CommOpDesc};
use lagom::eval::cache::eval_key;
use lagom::eval::{
    AnalyticEvaluator, EvalMode, Evaluator, Fidelity, SimEvaluator, TieredEvaluator,
};
use lagom::graph::{CompOpDesc, IterationSchedule, OverlapGroup};
use lagom::hw::ClusterSpec;
use lagom::report::evaluate;
use lagom::sim::SimEnv;
use lagom::tuner::{
    AutoCclTuner, ExhaustiveTuner, LagomTuner, LigerTuner, NcclTuner, Tuner,
};
use lagom::util::units::MIB;

/// Computation-bound overlap (Y >> X at sane configs) — the regime where
/// Lagom must beat comm-greedy tuning (Table 2's FSDP-style patterns).
fn comp_bound_group() -> OverlapGroup {
    OverlapGroup::with(
        "comp_bound",
        vec![
            CompOpDesc::ffn("ffn0", 2048, 2560, 10240, 2),
            CompOpDesc::ffn("ffn1", 2048, 2560, 10240, 2),
        ],
        vec![CommOpDesc::new("ar", CollectiveKind::AllReduce, 32 * MIB, 8)],
    )
}

/// Communication-bound overlap (X >> Y).
fn comm_bound_group() -> OverlapGroup {
    OverlapGroup::with(
        "comm_bound",
        vec![CompOpDesc::matmul("mm", 1024, 1024, 1024, 2)],
        vec![CommOpDesc::new("ar", CollectiveKind::AllReduce, 256 * MIB, 8)],
    )
}

fn schedule_of(groups: Vec<OverlapGroup>) -> IterationSchedule {
    let mut s = IterationSchedule::new("eval-test");
    for g in groups {
        s.push(g);
    }
    s
}

#[test]
fn analytic_and_simulated_tiers_agree_within_tolerance() {
    // The closed form can replace the simulator for *screening*: its
    // makespan must track ground truth within the error budget
    // `ablation_model_fit` establishes, and it must classify each fixture
    // onto the correct side of the comp/comm-bound divide.
    let cluster = ClusterSpec::cluster_b(1);
    let cfg = vec![CommConfig::default_ring()];
    for group in [comp_bound_group(), comm_bound_group()] {
        let mut analytic = AnalyticEvaluator::new(cluster.clone());
        let mut sim = SimEvaluator::deterministic(cluster.clone());
        let a = analytic.evaluate(&group, &cfg);
        let s = sim.evaluate(&group, &cfg);
        let rel = (a.makespan - s.makespan).abs() / s.makespan;
        assert!(
            rel < 0.35,
            "{}: analytic {} vs simulated {} ({}% off)",
            group.name,
            a.makespan,
            s.makespan,
            (rel * 100.0).round()
        );
        assert_eq!(
            a.comp_total > a.comm_total,
            s.comp_total > s.comm_total,
            "{}: tiers disagree on the comp/comm-bound regime",
            group.name
        );
        assert_eq!(a.fidelity, Fidelity::Analytic);
        assert_eq!(s.fidelity, Fidelity::Simulated);
        assert!(a.confidence < s.confidence);
    }
}

#[test]
fn tiered_tuning_matches_simulated_path_with_fewer_sim_calls() {
    // TieredEvaluator must never hand tuning a final config the plain
    // simulated path would reject: re-scored on fresh simulator noise,
    // the tiered-tuned schedule stays within tolerance of the
    // simulated-tuned one — while spending fewer simulator executions.
    let cluster = ClusterSpec::cluster_b(1);
    let s = schedule_of(vec![comp_bound_group(), comm_bound_group()]);

    let mut sim_eval = SimEvaluator::new(cluster.clone(), 17);
    let r_sim = LagomTuner::new(cluster.clone()).tune_schedule(&s, &mut sim_eval);

    let mut tiered_eval = TieredEvaluator::new(cluster.clone(), 17);
    let r_tiered = LagomTuner::new(cluster.clone()).tune_schedule(&s, &mut tiered_eval);

    // Fresh-noise scoring (the report's protocol): neither path gets
    // credit for overfitting its own noise stream.
    let z_sim = evaluate(&s, &r_sim.configs, &cluster, 1, 9090);
    let z_tiered = evaluate(&s, &r_tiered.configs, &cluster, 1, 9090);
    assert!(
        z_tiered <= z_sim * 1.10,
        "tiered config {z_tiered} must not lose to simulated path {z_sim}"
    );
    assert!(
        r_tiered.profile_calls < r_sim.profile_calls,
        "tiering must save simulator calls: {} vs {}",
        r_tiered.profile_calls,
        r_sim.profile_calls
    );
    let stats = tiered_eval.stats();
    assert!(stats.pruned > 0, "screening actually pruned candidates");
    // Every simulator execution is accounted for by a promotion (some
    // promotions may additionally be served from the memo cache).
    assert!(stats.promoted >= stats.sim_calls && stats.sim_calls > 0);
}

#[test]
fn memo_cache_hits_on_identical_content_only() {
    // Satellite acceptance: identical (group, config, seed) hits the memo
    // cache; changing any cost-affecting field — including the cluster's
    // link bandwidth — misses.
    let cluster = ClusterSpec::cluster_b(1);
    let group = comp_bound_group();
    let cfg = vec![CommConfig::default_ring()];

    let mut ev = SimEvaluator::new(cluster.clone(), 5);
    let first = ev.evaluate(&group, &cfg);
    let again = ev.evaluate(&group, &cfg);
    assert!(again.cached, "identical (group, config, seed) is a hit");
    assert_eq!(first.makespan, again.makespan);
    assert_eq!(ev.stats().sim_calls, 1);

    // Any cost-affecting change must miss: config, group content, seed,
    // noise level, and cluster bandwidth all key the cache.
    let base = eval_key(&cluster, &group, &cfg, 5, 3, 0.015);
    let mut faster = cluster.clone();
    faster.topology.intra.bandwidth *= 1.5;
    assert_ne!(base, eval_key(&faster, &group, &cfg, 5, 3, 0.015), "cluster bandwidth");
    let mut heavier = group.clone();
    heavier.comms[0].bytes *= 2;
    assert_ne!(base, eval_key(&cluster, &heavier, &cfg, 5, 3, 0.015), "group content");
    let mut other_cfg = cfg.clone();
    other_cfg[0].nt = 128;
    assert_ne!(base, eval_key(&cluster, &group, &other_cfg, 5, 3, 0.015), "config");
    assert_ne!(base, eval_key(&cluster, &group, &cfg, 6, 3, 0.015), "seed");

    // And the simulated numbers genuinely differ on the changed cluster.
    let mut ev_fast = SimEvaluator::new(faster, 5);
    let fast = ev_fast.evaluate(&group, &cfg);
    assert!(fast.makespan < first.makespan, "more bandwidth, faster comm");
}

#[test]
fn batch_and_single_evaluation_agree() {
    // evaluate_batch is an amortization, not a different measurement: on a
    // single-tier evaluator it must return exactly the per-call results.
    let cluster = ClusterSpec::cluster_b(1);
    let group = comp_bound_group();
    let frontier: Vec<Vec<CommConfig>> = [2u32, 8, 32]
        .iter()
        .map(|&nc| vec![CommConfig { nc, ..CommConfig::default_ring() }])
        .collect();
    let mut batch_ev = SimEvaluator::new(cluster.clone(), 11);
    let batched = batch_ev.evaluate_batch(&group, &frontier);
    let mut single_ev = SimEvaluator::new(cluster, 11);
    for (cand, b) in frontier.iter().zip(&batched) {
        let s = single_ev.evaluate(&group, cand);
        assert_eq!(s.makespan, b.makespan, "content-keyed noise: order-independent");
    }
}

#[test]
fn noise_level_sweeps_through_with_noise() {
    // `SimEnv::with_noise` lets the evaluation layer sweep sigma without
    // post-construction field mutation; sigma is part of the cache key.
    let cluster = ClusterSpec::cluster_b(1);
    let group = comm_bound_group();
    let cfg = vec![CommConfig::default_ring()];
    let quiet = SimEnv::with_noise(cluster.clone(), 3, 0.0);
    assert_eq!(quiet.noise_sigma, 0.0);
    let mut noisy = SimEvaluator::new(cluster.clone(), 3).with_noise_sigma(0.08);
    let mut calm = SimEvaluator::new(cluster, 3);
    let a = noisy.evaluate(&group, &cfg);
    let b = calm.evaluate(&group, &cfg);
    assert_ne!(a.makespan, b.makespan, "sigma changes the keyed noise stream");
}

fn tuner_by_name(name: &str, cluster: &ClusterSpec) -> Box<dyn Tuner> {
    match name {
        "lagom" => Box::new(LagomTuner::new(cluster.clone())),
        "autoccl" => Box::new(AutoCclTuner::new(cluster.clone())),
        "liger" => Box::new(LigerTuner::new(cluster.clone())),
        "nccl" => Box::new(NcclTuner::new(cluster.clone())),
        "exhaustive" => Box::new(ExhaustiveTuner::new(cluster.clone())),
        other => panic!("unknown tuner {other}"),
    }
}

#[test]
fn every_tuner_identical_at_jobs_1_vs_8() {
    // Satellite acceptance: the parallel evaluate_batch path must be
    // invisible to every tuner — final configs, iteration counts and
    // trajectories bitwise-identical at jobs=1 vs jobs=8, at both
    // simulated and tiered fidelity.
    let cluster = ClusterSpec::cluster_b(1);
    let s = schedule_of(vec![comp_bound_group()]);
    for name in ["lagom", "autoccl", "liger", "nccl", "exhaustive"] {
        let mut e1 = SimEvaluator::with_reps(cluster.clone(), 33, 1);
        let r1 = tuner_by_name(name, &cluster).tune_schedule(&s, &mut e1);
        let mut e8 = SimEvaluator::with_reps(cluster.clone(), 33, 1).with_jobs(8);
        let r8 = tuner_by_name(name, &cluster).tune_schedule(&s, &mut e8);
        assert_eq!(r1.configs, r8.configs, "{name}: sim-fidelity configs");
        assert_eq!(r1.iterations, r8.iterations, "{name}: sim-fidelity iterations");
        assert_eq!(r1.trajectory, r8.trajectory, "{name}: sim-fidelity trajectory");
        assert_eq!(e1.stats(), e8.stats(), "{name}: sim-fidelity eval accounting");
        assert_eq!(e1.stats().des_evals, 0, "{name}: homogeneous suite stays off the DES");

        let mut t1 = TieredEvaluator::new(cluster.clone(), 33);
        let q1 = tuner_by_name(name, &cluster).tune_schedule(&s, &mut t1);
        let mut t8 = TieredEvaluator::new(cluster.clone(), 33).with_jobs(8);
        let q8 = tuner_by_name(name, &cluster).tune_schedule(&s, &mut t8);
        assert_eq!(q1.configs, q8.configs, "{name}: tiered configs");
        assert_eq!(q1.trajectory, q8.trajectory, "{name}: tiered trajectory");
        assert_eq!(t1.stats(), t8.stats(), "{name}: tiered eval accounting");
    }
}

#[test]
fn cache_accounting_invariant_under_parallel_batches() {
    // Satellite acceptance: the sharded memo cache's relaxed counters must
    // satisfy `hits + misses == lookups` exactly once the workers have
    // joined — exercised through the real parallel evaluate_batch path at
    // jobs=8, with revisits to generate both hits and misses.
    let cluster = ClusterSpec::cluster_b(1);
    let group = comp_bound_group();
    let frontier: Vec<Vec<CommConfig>> = (0..24u32)
        .map(|i| vec![CommConfig { nc: 1 + i % 8, chunk: (64 + 64 * (i as u64 / 8)) * 1024, ..CommConfig::default_ring() }])
        .collect();
    for soa in [true, false] {
        // sigma == 0 so `soa = true` genuinely takes the SoA route.
        let mut ev = SimEvaluator::deterministic(cluster.clone()).with_jobs(8).with_soa(soa);
        ev.evaluate_batch(&group, &frontier);
        ev.evaluate_batch(&group, &frontier); // pure hits
        let c = ev.cache();
        assert_eq!(
            c.hits() + c.misses(),
            c.lookups(),
            "soa={soa}: every lookup is either a hit or a miss"
        );
        assert_eq!(c.lookups(), 2 * frontier.len() as u64, "soa={soa}");
        assert!(c.hits() >= frontier.len() as u64, "soa={soa}: second pass all hits");
        assert_eq!(ev.stats().des_evals, 0, "soa={soa}: homogeneous batches never hit the DES");
    }
}

#[test]
fn mixed_group_frontiers_fall_back_with_identical_results() {
    // Satellite acceptance: heterogeneous frontiers (different overlap
    // groups per candidate) must route to the per-candidate PR 3 path —
    // the SoA batch only ever sees homogeneous segments — and produce
    // results and accounting identical to evaluating one by one.
    let cluster = ClusterSpec::cluster_b(1);
    let g1 = comp_bound_group();
    let g2 = comm_bound_group();
    let cfg = |nc: u32| vec![CommConfig { nc, ..CommConfig::default_ring() }];
    // Strictly alternating: every segment is a singleton.
    let items: Vec<(&OverlapGroup, Vec<CommConfig>)> = vec![
        (&g1, cfg(1)),
        (&g2, cfg(1)),
        (&g1, cfg(2)),
        (&g2, cfg(2)),
        (&g1, cfg(4)),
        (&g2, cfg(4)),
    ];
    let mut mixed = SimEvaluator::deterministic(cluster.clone()).with_jobs(8);
    let got = mixed.evaluate_groups(&items);
    let mut reference =
        SimEvaluator::deterministic(cluster.clone()).with_plan(false).with_soa(false);
    let want: Vec<_> = items.iter().map(|(g, c)| reference.evaluate(g, c)).collect();
    assert_eq!(got, want, "heterogeneous frontier == one-by-one evaluation");
    // Singleton segments never engage the plan or SoA routes, so even the
    // full stats (plan counters included: all zero) must coincide.
    assert_eq!(mixed.stats(), reference.stats(), "accounting identical too");
    assert_eq!(mixed.stats().sim_calls, items.len() as u64, "no SoA batch formed");
    assert_eq!(mixed.stats().plan_compiles, 0, "singletons never compile a plan");
    assert_eq!(mixed.stats().des_evals, 0, "homogeneous cluster never routes to the DES");
}

#[test]
fn heterogeneous_clusters_route_to_the_des_tier_jobs_invariantly() {
    // PR 10 tentpole acceptance at the evaluator layer: a cluster the fast
    // path cannot express routes every cache miss to the discrete-event
    // tier — counted in `des_evals`, memoized like any other evaluation,
    // bitwise jobs-invariant, and never engaging the plan/SoA routes.
    let cluster = ClusterSpec::hetero_mixed();
    assert!(cluster.needs_des());
    let group = comp_bound_group();
    let frontier: Vec<Vec<CommConfig>> = [1u32, 4, 16, 64]
        .iter()
        .map(|&nc| vec![CommConfig { nc, ..CommConfig::default_ring() }])
        .collect();
    let mut e1 = SimEvaluator::with_reps(cluster.clone(), 77, 1);
    let a = e1.evaluate_batch(&group, &frontier);
    let mut e8 = SimEvaluator::with_reps(cluster.clone(), 77, 1).with_jobs(8);
    let b = e8.evaluate_batch(&group, &frontier);
    assert_eq!(a, b, "DES route is jobs-invariant");
    assert_eq!(e1.stats(), e8.stats(), "and so is its accounting");
    let s = e1.stats();
    assert_eq!(s.des_evals, frontier.len() as u64, "every miss ran on the DES");
    assert_eq!(s.sim_calls, s.des_evals, "des_evals is a subset of sim_calls");
    assert_eq!(s.plan_compiles, 0, "the compiled-plan route never engages");

    // Revisits are pure memo hits — the DES is not re-run.
    let c = e1.evaluate_batch(&group, &frontier);
    assert!(c.iter().all(|e| e.cached));
    assert_eq!(e1.stats().des_evals, frontier.len() as u64);

    // The deterministic DES also stays off plan/SoA and stays keyed.
    let mut det = SimEvaluator::deterministic(cluster);
    let d1 = det.evaluate_batch(&group, &frontier);
    let d2 = det.evaluate_batch(&group, &frontier);
    assert_eq!(d1.len(), d2.len());
    assert!(d2.iter().all(|e| e.cached));
    assert_eq!(det.stats().plan_compiles, 0);
    assert_eq!(det.stats().des_evals, frontier.len() as u64);
}

#[test]
fn mixed_group_plan_route_is_jobs_invariant_with_one_cache() {
    // PR 7 satellite: `evaluate_groups` splits a mixed-group frontier into
    // homogeneous segments, and the segments share the evaluator's single
    // PlanCache — each distinct group compiles once, a revisited group
    // hits, sim calls are counted exactly once per candidate, and none of
    // it depends on the worker count (results AND full stats identical at
    // jobs=1 vs jobs=8 through the plan route).
    let cluster = ClusterSpec::cluster_b(1);
    let g1 = comp_bound_group();
    let g2 = comm_bound_group();
    let cfg = |nc: u32| vec![CommConfig { nc, ..CommConfig::default_ring() }];
    // Multi-candidate segments: g1 ×3, g2 ×2, then g1 ×2 again — the
    // second g1 segment must *hit* the plan compiled for the first.
    let items: Vec<(&OverlapGroup, Vec<CommConfig>)> = vec![
        (&g1, cfg(1)),
        (&g1, cfg(2)),
        (&g1, cfg(4)),
        (&g2, cfg(1)),
        (&g2, cfg(2)),
        (&g1, cfg(8)),
        (&g1, cfg(16)),
    ];
    let mut serial = SimEvaluator::deterministic(cluster.clone());
    let a = serial.evaluate_groups(&items);
    let mut threaded = SimEvaluator::deterministic(cluster.clone()).with_jobs(8);
    let b = threaded.evaluate_groups(&items);
    assert_eq!(a, b, "plan route: jobs changes wall time only");
    assert_eq!(serial.stats(), threaded.stats(), "full stats, plan counters included");
    let s = serial.stats();
    assert_eq!(s.plan_compiles, 2, "each distinct group compiles exactly once");
    assert_eq!(s.plan_hits, 1, "the second g1 segment reuses the compiled plan");
    assert_eq!(s.sim_calls, items.len() as u64, "one sim call per candidate, no doubles");

    // And the numbers are the per-candidate scalar reference's, bitwise.
    let mut reference =
        SimEvaluator::deterministic(cluster).with_plan(false).with_soa(false);
    let want: Vec<_> = items.iter().map(|(g, c)| reference.evaluate(g, c)).collect();
    assert_eq!(a, want, "plan route == one-by-one evaluation");
    assert_eq!(
        serial.stats().route_invariant(),
        reference.stats().route_invariant(),
        "route-invariant accounting matches the scalar path"
    );
}

#[test]
fn eval_mode_factory_drives_all_three_tiers() {
    let cluster = ClusterSpec::cluster_b(1);
    let s = schedule_of(vec![comp_bound_group()]);
    for (mode, expect_sim) in [
        (EvalMode::Analytic, false),
        (EvalMode::Simulated, true),
        (EvalMode::Tiered, true),
    ] {
        let mut ev = lagom::eval::make_evaluator(mode, &cluster, 23);
        let r = LagomTuner::new(cluster.clone()).tune_schedule(&s, ev.as_mut());
        assert_eq!(r.configs.len(), 1, "{mode:?}");
        assert_eq!(r.profile_calls > 0, expect_sim, "{mode:?}: sim usage");
        assert!(ev.stats().evaluations > 0);
    }
}
